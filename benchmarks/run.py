"""Benchmark driver: ``python -m benchmarks.run [--only substr]``.

One function per paper table/figure (bench_paper) + kernel micros
(bench_kernels).  Prints ``name,us_per_call,derived`` CSV; per-program
HLO cost summaries come from ``benchmarks.hlo_cost``.

``--json`` maintains BENCH_kernels.json as the recorded perf artifact:
``results`` holds the latest value per section (merged, so a --only'd
run refreshes its own rows without wiping everyone else's) and
``trajectory`` appends one run record per invocation — git sha,
timestamp, backend/device count, and the sections this run produced —
so the artifact CI uploads preserves the perf history across PRs
instead of only the final overwrite.  Each write also stamps
``calibration.reference_us`` — the wall time of a fixed numpy-only
workload on the machine producing the artifact — which
benchmarks/check_regression.py re-measures at gate time to normalize
the committed qps by runner speed before gating the ``results``
sections.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _git_sha() -> str:
    import subprocess
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def write_json(path: str) -> None:
    import datetime
    import json
    import os

    import jax

    from benchmarks.common import RESULTS
    from benchmarks.check_regression import reference_workload_us

    doc = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
    merged = doc.get("results", {})
    merged.update(RESULTS)
    # runner-speed stamp: check_regression re-measures this fixed
    # numpy workload at gate time and scales the committed qps by the
    # ratio, so the gate compares work, not machines.  Stamped into
    # BOTH the top-level calibration (gates the ``results`` overwrite)
    # and this run's trajectory record — a trajectory row without its
    # own stamp cannot be speed-normalized against any other row, so
    # the perf history would be machine noise; check_trajectory
    # rejects such records.
    calibration = {"reference_us": round(reference_workload_us(), 1)}
    trajectory = doc.get("trajectory", [])
    trajectory.append({
        "sha": _git_sha(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "reference_us": calibration["reference_us"],
        "results": dict(RESULTS),
    })
    with open(path, "w") as f:
        json.dump({"backend": jax.default_backend(),
                   "calibration": calibration,
                   "results": merged,
                   "trajectory": trajectory}, f, indent=2,
                  sort_keys=True)
    print(f"# wrote {len(RESULTS)} rows to {path} "
          f"({len(merged)} total, {len(trajectory)} trajectory runs)",
          flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="merge this run's rows into the JSON artifact "
                         "and append a trajectory record; '' disables")
    args = ap.parse_args()

    sys.path.insert(0, "/root/repo/src")
    from benchmarks import bench_kernels, bench_paper

    print("name,us_per_call,derived")
    failures = 0
    for fn in bench_paper.ALL + bench_kernels.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        t0 = time.time()
        try:
            fn()
            print(f"# {fn.__name__} done in {time.time() - t0:.1f}s",
                  flush=True)
        except Exception:    # noqa: BLE001 — report and continue
            failures += 1
            print(f"# {fn.__name__} FAILED:", flush=True)
            traceback.print_exc()
    if args.json:
        write_json(args.json)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
