"""Paper-table benchmarks (one function per paper table/figure).

Scales are container-sized but structure-preserving: every claim the
paper makes qualitatively (orders-of-magnitude index shrink with gamma,
query speedups vs serial scans and multi-index baselines, approximate
quality, DTW pruning/abandoning power) is measured and asserted here;
EXPERIMENTS.md quotes these numbers next to the paper's.

CSV output: name,us_per_call,derived.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (CMRILite, IndIntLite, emit, mass_knn,
                               timer, ucr_scan_knn)
from repro.core.engine import QuerySpec, UlisseEngine
from repro.core.index import build_index, index_stats
from repro.core.search import brute_force_knn
from repro.core.types import Collection, EnvelopeParams
from repro.train.data import series_batches

SEED = 42
NS, SLEN = 400, 256          # collection: 400 series of length 256
LMIN, LMAX, SEG = 160, 256, 16


def _collection(kind="randomwalk", ns=NS, slen=SLEN):
    return series_batches(ns, slen, seed=SEED, kind=kind)


def _queries(data, qlen, m=5, noise=0.05, rng=None):
    rng = rng or np.random.default_rng(SEED + 1)
    out = []
    for _ in range(m):
        i = rng.integers(0, data.shape[0])
        o = rng.integers(0, data.shape[1] - qlen + 1)
        out.append(data[i, o:o + qlen]
                   + rng.normal(size=qlen).astype(np.float32) * noise)
    return out


# ----------------------------------------------------------------- Fig 14/22
def bench_envelope_building():
    """Index construction time vs gamma, and vs length range."""
    data = _collection()
    coll = Collection.from_array(data)
    sizes = {}
    for gamma in (0, 16, 48, 96):
        p = EnvelopeParams(lmin=LMIN, lmax=LMAX, gamma=gamma,
                           seg_len=SEG, znorm=True)
        t = timer(lambda pp=p: build_index(coll, pp), repeats=2)
        idx = build_index(coll, p)
        n_env = index_stats(idx, p)["num_envelopes"]
        sizes[gamma] = n_env
        emit(f"fig14_build_gamma{gamma}", t, f"envelopes={n_env}")
    assert sizes[0] > 20 * sizes[96], "gamma must shrink the index >20x"
    for rng_len in (32, 64, 96):
        p = EnvelopeParams(lmin=LMAX - rng_len, lmax=LMAX, gamma=16,
                           seg_len=SEG, znorm=True)
        t = timer(lambda pp=p: build_index(coll, pp), repeats=2)
        emit(f"fig14b_build_range{rng_len}", t, "")


# ----------------------------------------------------------------- Fig 15/16
def bench_query_vs_gamma():
    """Query time / pruning power vs gamma, +-Z-normalization."""
    data = _collection()
    coll = Collection.from_array(data)
    for znorm in (True, False):
        tag = "fig16" if znorm else "fig15"
        for gamma in (0, 16, 96):
            p = EnvelopeParams(lmin=LMIN, lmax=LMAX, gamma=gamma,
                               seg_len=SEG, znorm=znorm)
            eng = UlisseEngine.from_index(build_index(coll, p))
            qs = _queries(data, 192)
            t0 = time.perf_counter()
            prunes = []
            for q in qs:
                r = eng.search(q, QuerySpec(k=1))
                prunes.append(r.stats.pruning_power)
            dt = (time.perf_counter() - t0) / len(qs)
            emit(f"{tag}_query_gamma{gamma}", dt,
                 f"pruning={np.mean(prunes):.3f}")


# ----------------------------------------------------------------- Fig 17/23
def bench_vs_serial_scans():
    """ULISSE vs UCR-style scan vs MASS; correctness cross-checked."""
    data = _collection()
    coll = Collection.from_array(data)
    p = EnvelopeParams(lmin=LMIN, lmax=LMAX, gamma=48, seg_len=SEG,
                       znorm=True)
    eng = UlisseEngine.from_index(build_index(coll, p))
    speedups = []
    for qlen in (160, 192, 256):
        qs = _queries(data, qlen, m=3)
        # warm the jitted paths
        eng.search(qs[0], QuerySpec(k=1))
        ucr_scan_knn(data, qs[0], 1, True)
        mass_knn(data, qs[0], 1)
        t_u = t_s = t_m = 0.0
        for q in qs:
            t0 = time.perf_counter()
            ru = eng.search(q, QuerySpec(k=1))
            t_u += time.perf_counter() - t0
            t0 = time.perf_counter()
            rs = ucr_scan_knn(data, q, 1, True)
            t_s += time.perf_counter() - t0
            t0 = time.perf_counter()
            rm = mass_knn(data, q, 1)
            t_m += time.perf_counter() - t0
            assert abs(ru.dists[0] - rs[0]) < 0.05, (ru.dists, rs)
            assert abs(rm[0] - rs[0]) < 0.05, (rm, rs)
        emit(f"fig17_ulisse_q{qlen}", t_u / 3, "")
        emit(f"fig17_ucrscan_q{qlen}", t_s / 3, "")
        emit(f"fig17_mass_q{qlen}", t_m / 3, "")
        speedups.append(t_s / max(t_u, 1e-9))
    emit("fig17_speedup_vs_ucr", 0.0, f"x{np.mean(speedups):.2f}")


# ----------------------------------------------------------------- Fig 18/19
def bench_query_length_ranges():
    data = _collection()
    coll = Collection.from_array(data)
    for lo in (96, 160, 224):
        p = EnvelopeParams(lmin=lo, lmax=LMAX, gamma=32, seg_len=SEG,
                           znorm=True)
        idx = build_index(coll, p)
        eng = UlisseEngine.from_index(idx)
        qs = _queries(data, (lo + LMAX) // 2 // SEG * SEG, m=3)
        t0 = time.perf_counter()
        for q in qs:
            eng.search(q, QuerySpec(k=1))
        emit(f"fig18_range_{lo}_{LMAX}",
             (time.perf_counter() - t0) / 3,
             f"envs={index_stats(idx, p)['num_envelopes']}")


# ----------------------------------------------------------------- Fig 20/21
def bench_approx_quality():
    data = _collection()
    coll = Collection.from_array(data)
    p = EnvelopeParams(lmin=LMIN, lmax=LMAX, gamma=16, seg_len=SEG,
                       znorm=True)
    eng = UlisseEngine.from_index(build_index(coll, p))
    ranks, leaves = [], []
    for q in _queries(data, 192, m=10, noise=0.02):
        a = eng.search(q, QuerySpec(mode="approx", k=1))
        ref = brute_force_knn(coll, q, k=100, znorm=True)
        key = (a.series[0], a.offsets[0])
        pairs = list(zip(ref.series, ref.offsets))
        ranks.append(pairs.index(key) if key in pairs else 100)
        leaves.append(a.stats.leaves_visited)
    emit("fig20_approx_rank_median", 0.0, f"{np.median(ranks):.0f}")
    emit("fig21_leaves_visited_mean", 0.0, f"{np.mean(leaves):.1f}")
    assert np.mean(leaves) <= 8, "approx must visit few leaves"
    assert np.median(ranks) <= 20, f"approx quality degraded: {ranks}"


# ----------------------------------------------------------------- Fig 25/26
def bench_dtw():
    """DTW query answering: pruning + abandoning power vs warping win.
    Random-walk data (the paper's synthetic workload): periodic series
    make every subsequence a near-match, which legitimately floors the
    abandoning power (no bsf can prune lookalikes)."""
    data = _collection("randomwalk")
    coll = Collection.from_array(data)
    p = EnvelopeParams(lmin=LMIN, lmax=LMAX, gamma=48, seg_len=SEG,
                       znorm=True)
    eng = UlisseEngine.from_index(build_index(coll, p))
    for wfrac in (0.05, 0.10):
        r = int(192 * wfrac)
        prunes, abandons, ts = [], [], []
        for q in _queries(data, 192, m=3):
            t0 = time.perf_counter()
            res = eng.search(q, QuerySpec(k=1, measure="dtw", r=r))
            ts.append(time.perf_counter() - t0)
            prunes.append(res.stats.pruning_power)
            abandons.append(res.stats.abandoning_power)
        emit(f"fig25_dtw_w{int(wfrac * 100)}", float(np.mean(ts)),
             f"pruning={np.mean(prunes):.3f},"
             f"abandoning={np.mean(abandons):.3f}")
        assert np.mean(abandons) >= 0.3, \
            f"LB_Keogh abandoning too weak: {abandons}"


# ----------------------------------------------------------------- Fig 27
def bench_knn_scaling():
    data = _collection()
    coll = Collection.from_array(data)
    p = EnvelopeParams(lmin=LMIN, lmax=LMAX, gamma=48, seg_len=SEG,
                       znorm=True)
    eng = UlisseEngine.from_index(build_index(coll, p))
    q = _queries(data, LMIN, m=1)[0]
    for k in (1, 10, 50):
        t0 = time.perf_counter()
        eng.search(q, QuerySpec(k=k))
        emit(f"fig27_knn_k{k}", time.perf_counter() - t0, "")


# ----------------------------------------------------------------- Fig 29
def bench_vs_indint():
    data = _collection(ns=100, slen=512).astype(np.float32)
    coll = Collection.from_array(data)
    p = EnvelopeParams(lmin=128, lmax=256, gamma=64, seg_len=16,
                       znorm=False)
    idx = build_index(coll, p)
    eng = UlisseEngine.from_index(idx)
    ii = IndIntLite(data, prefix_len=128)
    stats = index_stats(idx, p)
    emit("fig29_index_records_ulisse", 0.0,
         f"{stats['num_envelopes']}")
    emit("fig29_index_records_indint", 0.0,
         f"{ii.prefixes.shape[0] * ii.prefixes.shape[1]}")
    for qlen in (128, 192, 256):
        q = _queries(data, qlen, m=1, noise=0.01)[0]
        t0 = time.perf_counter()
        ru = eng.search(q, QuerySpec(k=1))
        tu = time.perf_counter() - t0
        eps = float(ru.dists[0]) * 2 + 1e-3
        t0 = time.perf_counter()
        di, checked = ii.knn(q, 1, eps=eps)
        ti = time.perf_counter() - t0
        emit(f"fig29_ulisse_q{qlen}", tu, "")
        emit(f"fig29_indint_q{qlen}", ti, f"verified={checked}")
        assert abs(ru.dists[0] - di[0]) < 0.05


# ----------------------------------------------------------------- Fig 30
def bench_range_queries():
    data = _collection("periodic", ns=200)
    coll = Collection.from_array(data)
    p = EnvelopeParams(lmin=LMIN, lmax=LMAX, gamma=48, seg_len=SEG,
                       znorm=False)
    eng = UlisseEngine.from_index(build_index(coll, p))
    for qlen in (160, 256):
        q = _queries(data, qlen, m=1)[0]
        nn = eng.search(q, QuerySpec(k=1))
        eps = float(nn.dists[0]) * 2
        t0 = time.perf_counter()
        res = eng.search(q, QuerySpec(eps=eps, chunk_size=2048))
        emit(f"fig30_range_q{qlen}", time.perf_counter() - t0,
             f"hits={len(res.dists)}")
        # selectivity check vs brute force
        ref = brute_force_knn(coll, q, k=200, znorm=False)
        expect = int((ref.dists <= eps).sum())
        assert abs(len(res.dists) - expect) <= max(2, expect // 10)


# ----------------------------------------------------------------- CMRI
def bench_vs_cmri():
    data = _collection(ns=150)
    coll = Collection.from_array(data)
    p = EnvelopeParams(lmin=LMIN, lmax=LMAX, gamma=48, seg_len=SEG,
                       znorm=False)
    t_build_u = timer(lambda: build_index(coll, p), repeats=1)
    idx = build_index(coll, p)
    t_build_c = timer(lambda: CMRILite(data, (160, 192, 224, 256)),
                      repeats=1)
    cmri = CMRILite(data, (160, 192, 224, 256))
    emit("cmri_build_ulisse", t_build_u,
         f"records={index_stats(idx, p)['num_envelopes']}")
    emit("cmri_build_cmri", t_build_c,
         f"records={sum(np.prod(v[0].shape[:2]) for v in cmri.tables.values())}")
    q = _queries(data, 192, m=1, noise=0.01)[0]
    t0 = time.perf_counter()
    ru = UlisseEngine.from_index(idx).search(q, QuerySpec(k=1))
    tu = time.perf_counter() - t0
    t0 = time.perf_counter()
    dc, checked = cmri.knn(q, 1)
    tc = time.perf_counter() - t0
    emit("cmri_query_ulisse", tu, "")
    emit("cmri_query_cmri", tc, f"verified={checked}")
    assert abs(ru.dists[0] - dc[0]) < 0.05


ALL = [bench_envelope_building, bench_query_vs_gamma,
       bench_vs_serial_scans, bench_query_length_ranges,
       bench_approx_quality, bench_dtw, bench_knn_scaling,
       bench_vs_indint, bench_range_queries, bench_vs_cmri]
